"""Repo-invariant lint rules (REP001–REP008).

These encode invariants the codebase already depends on but nothing
enforced until now:

REP001  clock-injectable modules (``serving/``, ``cluster/``,
        ``core/restore.py``) must not *call* ``time.time`` /
        ``time.monotonic`` / ``time.perf_counter`` / ``time.sleep`` in a
        function body.  The injected-clock seam — ``clock=time.monotonic``
        as a default parameter value — is an ``ast.Attribute`` reference,
        not a Call, and stays legal.
REP002  instance state transitions go through the state-machine methods:
        raw ``<obj>.state = State.X`` writes are only legal inside
        ``FunctionInstance``'s own transition methods.
REP003  the process-wide ``WS_CACHE`` is touched only through its
        single-flight API: no private-attribute reads/writes from outside
        ``core/reap.py``.
REP004  every module that spawns a ``threading.Thread`` must contain a
        reachable ``.join(`` call, and a ``ThreadPoolExecutor`` created
        outside a ``with`` block requires a ``.shutdown(`` call somewhere
        in the module.
REP005  the seven ``StageTimings`` stage fields are written only through a
        ``timings``/``stages`` receiver (PR 6's source-of-truth contract);
        flat writes like ``report.install_s = ...`` are flagged.
REP006  telemetry emission goes through ``MetricsRegistry``: a *new*
        ``stats()``-style method building an ad-hoc stats dict in the
        clock-injectable scope (``serving/``, ``cluster/``,
        ``core/restore.py``) is flagged unless it is one of the documented
        snapshotter surfaces (telemetry/schema.py) listed in
        ``REP006_STATS_SURFACES``.
REP007  WS bytes are content-addressed: the ``.ws`` file may be a chunk
        manifest, so *reading* it as raw bytes (``open``/``os.open``/
        ``PageSource``/``np.memmap``/``np.fromfile`` over a ``ws_path()``
        argument) is only legal inside ``core/pagestore.py`` and the
        legacy flat-format seam (``core/reap.py::_read_ws_flat``).
        Metadata probes (``getmtime``/``exists``) and write-mode opens
        stay legal everywhere.
REP008  the page data plane lives behind ``src/repro/transport/``:
        importing ``socket`` or ``multiprocessing.shared_memory``
        anywhere else is flagged.  The rest of the tree talks chunks and
        manifests, never file descriptors — keeping every raw-wire and
        shared-memory touchpoint behind one seam.  (core/restore.py's
        ``connect_handshake`` socketpair loopback predates the transport
        layer and is accepted via the analysis baseline, not a code
        exemption.)
"""
from __future__ import annotations

import ast
import os
from typing import Optional

from .findings import Finding, dedup

TIME_CALLS = {"time", "monotonic", "perf_counter", "sleep",
              "monotonic_ns", "perf_counter_ns", "time_ns"}

REP001_SCOPES = ("serving/", "cluster/")
REP001_FILES = ("core/restore.py",)

STATE_TRANSITION_METHODS = {
    ("FunctionInstance", "__init__"),
    ("FunctionInstance", "_adopt"),
    ("FunctionInstance", "try_acquire"),
    ("FunctionInstance", "release"),
    ("FunctionInstance", "try_reclaim"),
    ("FunctionInstance", "reclaim"),
}

# StageTimings dataclass fields (prefetch_s is a derived property and the
# Monitor keeps a flat legacy copy, so it is deliberately not listed).
STAGE_FIELDS = {"load_vmm_s", "connection_s", "ws_fetch_s", "install_s",
                "materialize_s", "materialize_to_resident_s", "tail_wait_s"}
STAGE_RECEIVERS = {"timings", "stages", "t"}

WS_CACHE_PRIVATE = {"_entries", "_inflight", "_gens", "_order", "_lock",
                    "_bytes", "_listeners"}

# REP006: the documented stats()/snapshotter surfaces (telemetry/schema.py).
# Anything else named like a stats emitter that builds a dict literal in
# the clock-injectable scope should be a MetricsRegistry emission instead.
REP006_STATS_SURFACES = {
    ("serving/router.py", "Router.stats"),
    ("serving/orchestrator.py", "Orchestrator.tail_stats"),
    ("serving/policy.py", "PrewarmPolicy.stats"),
    ("cluster/node.py", "WorkerNode.stats"),
    ("cluster/scheduler.py", "ClusterRouter.stats"),
    ("cluster/demand.py", "DemandAggregator.stats"),
    ("cluster/snapstore.py", "ShardedSnapshotStore.stats"),
}


# REP007: the only places allowed to read WS-record bytes directly.  The
# page store owns the chunk data; _read_ws_flat is the format-versioned
# fallback for legacy flat WS files (and the flat baseline arm).
REP007_ALLOWED_FILES = {"core/pagestore.py"}
REP007_SEAMS = {("core/reap.py", "_read_ws_flat")}
REP007_READER_NAMES = {"PageSource"}
REP007_READER_DOTTED = {("os", "open"), ("np", "memmap"), ("np", "fromfile"),
                        ("numpy", "memmap"), ("numpy", "fromfile")}

# REP008: only the transport package may touch the raw data plane.
REP008_ALLOWED_PREFIX = "transport/"
REP008_MODULES = ("socket", "multiprocessing.shared_memory")


def _stats_like(name: str) -> bool:
    return (name in ("stats", "metrics")
            or name.endswith("_stats") or name.endswith("_metrics"))


def _in_rep001_scope(rel: str) -> bool:
    return rel.startswith(REP001_SCOPES) or rel in REP001_FILES


def _qualname_stack(stack: list) -> str:
    return ".".join(stack) if stack else "<module>"


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str) -> None:
        self.rel = rel
        self.stack: list[str] = []      # enclosing class/function names
        self.findings: list[Finding] = []

    # -- scope bookkeeping -----------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_rep006(node)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- REP008 -----------------------------------------------------------

    def _rep008(self, lineno: int, what: str) -> None:
        self.findings.append(Finding(
            rule="REP008", path=self.rel, line=lineno,
            symbol=_qualname_stack(self.stack),
            message=(f"raw data-plane import ({what}) outside "
                     "src/repro/transport/; sockets and shared memory are "
                     "confined behind the transport seam"),
            detail=f"data-plane-import:{what}"))

    def visit_Import(self, node: ast.Import) -> None:
        if not self.rel.startswith(REP008_ALLOWED_PREFIX):
            for alias in node.names:
                if (alias.name in REP008_MODULES
                        or alias.name.startswith("socket.")):
                    self._rep008(node.lineno, alias.name)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if not self.rel.startswith(REP008_ALLOWED_PREFIX):
            mod = node.module or ""
            if (mod in REP008_MODULES or mod.startswith("socket.")
                    or mod.startswith("multiprocessing.shared_memory.")):
                self._rep008(node.lineno, mod)
            elif mod == "multiprocessing":
                for alias in node.names:
                    if alias.name == "shared_memory":
                        self._rep008(node.lineno,
                                     "multiprocessing.shared_memory")
        self.generic_visit(node)

    # -- REP006 -----------------------------------------------------------

    def _check_rep006(self, node: ast.FunctionDef) -> None:
        """Flag a new stats-emitting method building an ad-hoc dict in the
        clock-injectable scope: telemetry belongs in MetricsRegistry, and
        snapshotter surfaces belong in the documented schema."""
        if not _in_rep001_scope(self.rel) or not _stats_like(node.name):
            return
        qual = ".".join([*self.stack, node.name])
        if (self.rel, qual) in REP006_STATS_SURFACES:
            return
        if not self._builds_stats_dict(node):
            return
        self.findings.append(Finding(
            rule="REP006", path=self.rel, line=node.lineno, symbol=qual,
            message=("new ad-hoc stats dict surface; emit through "
                     "MetricsRegistry (repro.telemetry) or add the surface "
                     "to the documented snapshotter schema "
                     "(telemetry/schema.py + REP006_STATS_SURFACES)"),
            detail=f"adhoc-stats:{node.name}"))

    @staticmethod
    def _builds_stats_dict(node: ast.FunctionDef) -> bool:
        """True when the function both returns something and contains a
        multi-key dict literal (covers ``return {...}`` and the
        ``out = {...}; ...; return out`` shape alike)."""
        has_return = any(isinstance(n, ast.Return) and n.value is not None
                         for n in ast.walk(node))
        has_dict = any(isinstance(n, ast.Dict) and len(n.keys) >= 2
                       for n in ast.walk(node))
        return has_return and has_dict

    # -- REP001 -----------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if (_in_rep001_scope(self.rel)
                and isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == "time"
                and f.attr in TIME_CALLS):
            self.findings.append(Finding(
                rule="REP001", path=self.rel, line=node.lineno,
                symbol=_qualname_stack(self.stack),
                message=(f"direct time.{f.attr}() call in a clock-injectable "
                         "module; route through the injected clock/sleep "
                         "parameter instead"),
                detail=f"time.{f.attr}"))
        self._check_rep007(node)
        self.generic_visit(node)

    # -- REP007 -----------------------------------------------------------

    @staticmethod
    def _open_mode(node: ast.Call) -> str:
        if (len(node.args) >= 2 and isinstance(node.args[1], ast.Constant)
                and isinstance(node.args[1].value, str)):
            return node.args[1].value
        for kw in node.keywords:
            if (kw.arg == "mode" and isinstance(kw.value, ast.Constant)
                    and isinstance(kw.value.value, str)):
                return kw.value.value
        return "r"

    @staticmethod
    def _has_ws_path_call(expr: ast.expr) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                g = n.func
                if isinstance(g, ast.Name) and g.id == "ws_path":
                    return True
                if isinstance(g, ast.Attribute) and g.attr == "ws_path":
                    return True
        return False

    def _check_rep007(self, node: ast.Call) -> None:
        """Flag raw byte reads of a ``ws_path()`` file outside the page
        store and the legacy fallback seam."""
        if self.rel in REP007_ALLOWED_FILES:
            return
        fn = self.stack[-1] if self.stack else None
        if (self.rel, fn) in REP007_SEAMS:
            return
        f = node.func
        target = None
        if isinstance(f, ast.Name):
            if f.id == "open":
                mode = self._open_mode(node)
                if any(c in mode for c in "wax"):
                    return               # writers are legal everywhere
                target = "open"
            elif f.id in REP007_READER_NAMES:
                target = f.id
        elif isinstance(f, ast.Attribute):
            if f.attr in REP007_READER_NAMES:
                target = f.attr
            elif (isinstance(f.value, ast.Name)
                    and (f.value.id, f.attr) in REP007_READER_DOTTED):
                target = f"{f.value.id}.{f.attr}"
        if target is None:
            return
        if not any(self._has_ws_path_call(a)
                   for a in [*node.args, *[k.value for k in node.keywords]]):
            return
        self.findings.append(Finding(
            rule="REP007", path=self.rel, line=node.lineno,
            symbol=_qualname_stack(self.stack),
            message=(f"direct WS byte read ({target} over ws_path(...)); "
                     "the .ws file may be a chunk manifest — go through "
                     "core/pagestore.py or the _read_ws_flat legacy seam"),
            detail=f"ws-byte-read:{target}"))

    # -- REP002 / REP005 (attribute writes) -------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._check_attr_write(tgt, node.value, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._check_attr_write(node.target, node.value, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_attr_write(node.target, None, node.lineno)
        self.generic_visit(node)

    def _check_attr_write(self, tgt: ast.expr, value: Optional[ast.expr],
                          lineno: int) -> None:
        if not isinstance(tgt, ast.Attribute):
            return
        # REP002: raw `.state = State.X`
        if tgt.attr == "state" and self._is_state_value(value):
            where = (self.stack[-2] if len(self.stack) >= 2 else None,
                     self.stack[-1] if self.stack else None)
            if where not in STATE_TRANSITION_METHODS:
                self.findings.append(Finding(
                    rule="REP002", path=self.rel, line=lineno,
                    symbol=_qualname_stack(self.stack),
                    message=("raw instance-state write; use the "
                             "state-machine methods (try_acquire/release/"
                             "try_reclaim/reclaim) instead"),
                    detail="raw-state-write"))
        # REP003: assignment onto WS_CACHE attributes
        if isinstance(tgt.value, ast.Name) and tgt.value.id == "WS_CACHE":
            self.findings.append(Finding(
                rule="REP003", path=self.rel, line=lineno,
                symbol=_qualname_stack(self.stack),
                message="direct write to WS_CACHE attribute; use the "
                        "single-flight API",
                detail=f"write:{tgt.attr}"))
        # REP005: flat stage-field writes outside a timings receiver
        if tgt.attr in STAGE_FIELDS and self.rel != "core/reap.py":
            recv = tgt.value
            recv_name = None
            if isinstance(recv, ast.Name):
                recv_name = recv.id
            elif isinstance(recv, ast.Attribute):
                recv_name = recv.attr
            if recv_name not in STAGE_RECEIVERS:
                self.findings.append(Finding(
                    rule="REP005", path=self.rel, line=lineno,
                    symbol=_qualname_stack(self.stack),
                    message=(f"stage timing '{tgt.attr}' written outside "
                             "StageTimings; stage seconds are "
                             "StageTimings-authoritative (PR 6 contract)"),
                    detail=f"flat-write:{tgt.attr}"))

    @staticmethod
    def _is_state_value(value: Optional[ast.expr]) -> bool:
        """True for `State.X` / `<mod>.State.X` values (and unknown for
        AugAssign, which we treat as suspicious only for State attrs)."""
        if value is None:
            return False
        node = value
        while isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name) and node.value.id == "State":
                return True
            node = node.value
        return False

    # -- REP003 (reads of private attrs) ----------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (isinstance(node.value, ast.Name) and node.value.id == "WS_CACHE"
                and node.attr.startswith("_")
                and self.rel != "core/reap.py"):
            self.findings.append(Finding(
                rule="REP003", path=self.rel, line=node.lineno,
                symbol=_qualname_stack(self.stack),
                message=(f"WS_CACHE private attribute '{node.attr}' touched "
                         "outside core/reap.py; use the single-flight API"),
                detail=f"read:{node.attr}"))
        self.generic_visit(node)


def _module_rep004(rel: str, tree: ast.Module, src: str) -> list[Finding]:
    """Module-granular thread-lifecycle audit."""
    findings: list[Finding] = []
    spawns_thread: Optional[int] = None
    bare_pool: Optional[int] = None
    with_pool_ctxs: set[int] = set()

    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Call):
                    with_pool_ctxs.add(id(ctx))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        path_parts = []
        f = node.func
        while isinstance(f, ast.Attribute):
            path_parts.append(f.attr)
            f = f.value
        if isinstance(f, ast.Name):
            path_parts.append(f.id)
        name = path_parts[0] if path_parts else ""
        if name == "Thread":
            spawns_thread = spawns_thread or node.lineno
        elif name == "ThreadPoolExecutor" and id(node) not in with_pool_ctxs:
            bare_pool = bare_pool or node.lineno

    has_join = ".join(" in src
    has_shutdown = ".shutdown(" in src or "shutdown(" in src
    if spawns_thread is not None and not has_join:
        findings.append(Finding(
            rule="REP004", path=rel, line=spawns_thread, symbol="<module>",
            message=("module spawns threading.Thread but contains no "
                     ".join() path; every spawned thread needs a reachable "
                     "join/quiesce/cancel"),
            detail="thread-without-join"))
    if bare_pool is not None and not has_shutdown:
        findings.append(Finding(
            rule="REP004", path=rel, line=bare_pool, symbol="<module>",
            message=("ThreadPoolExecutor created outside a with-block and "
                     "the module has no .shutdown() path"),
            detail="pool-without-shutdown"))
    return findings


def analyze_lint(root: str) -> list[Finding]:
    """Run REP001–REP008 over every ``.py`` under ``root``."""
    findings: list[Finding] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            with open(full, "r", encoding="utf-8") as fh:
                src = fh.read()
            try:
                tree = ast.parse(src, filename=rel)
            except SyntaxError:
                continue
            linter = _Linter(rel)
            linter.visit(tree)
            findings.extend(linter.findings)
            findings.extend(_module_rep004(rel, tree, src))
    return dedup(findings)
