"""Opt-in runtime lock sanitizer (``REPRO_LOCK_SANITIZER=1``).

``enable()`` monkeypatches ``threading.Lock``/``RLock``/``Condition`` with
factories that hand back sanitized wrappers *only* when the caller's module
is part of the ``repro`` package (looked up from the calling frame), so
pytest internals, JAX, and the stdlib keep the real primitives.

Each wrapper records, per thread, the stack of currently-held locks.  On
every acquisition that happens while other locks are held, the sanitizer
inserts site-order edges ``held -> acquired`` into a global order graph and
runs an incremental cycle check: the first edge that closes a cycle raises
(or records, in deferred mode) a :class:`LockOrderViolation` carrying a
witness trace — both conflicting acquisition stacks with file:line sites.

``Condition.wait`` and a patched ``time.sleep`` additionally detect
*held-across-blocking*: blocking while holding any sanitized lock other
than the one the condition itself releases.

Locks are identified by their **creation site** (``file:line``), not object
identity, so the graph stays small and stable across instances — two
``FunctionRecord.lock`` conditions created at the same line are one node,
which is exactly the granularity the static pass reasons at.
"""
from __future__ import annotations

import os
import sys
import threading
import time
import traceback


_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition
_REAL_SLEEP = time.sleep


class LockOrderViolation(RuntimeError):
    pass


class HeldAcrossBlocking(RuntimeError):
    pass


def _creation_site(depth: int = 1) -> str:
    """file:line of the frame ``depth`` levels above the caller."""
    f = sys._getframe(depth + 1)
    return f"{os.path.basename(f.f_code.co_filename)}:{f.f_lineno}"


def _caller_module(depth: int = 1) -> str:
    try:
        return sys._getframe(depth + 1).f_globals.get("__name__", "")
    except ValueError:
        return ""


class SanitizerState:
    """All sanitizer bookkeeping.  Tests construct private instances; the
    process-wide singleton is :data:`STATE`."""

    def __init__(self, raise_on_violation: bool = True) -> None:
        self._mu = _REAL_LOCK()
        self.raise_on_violation = raise_on_violation
        # site -> set of successor sites, with a witness per edge
        self.edges: dict[str, set[str]] = {}
        self.edge_witness: dict[tuple[str, str], str] = {}
        self.violations: list[dict] = []
        self._tls = threading.local()

    # -- per-thread held stack -------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def held_sites(self) -> list[str]:
        return [site for site, _n in self._stack()]

    # -- graph ------------------------------------------------------------

    def _find_path(self, src: str, dst: str) -> list[str] | None:
        """DFS path src -> dst in the edge graph (for witness rendering)."""
        seen = {src}
        stack = [(src, [src])]
        while stack:
            node, path = stack.pop()
            for nxt in self.edges.get(node, ()):
                if nxt == dst:
                    return path + [dst]
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def record_acquire(self, site: str) -> None:
        stack = self._stack()
        new_witness = "".join(traceback.format_stack(limit=12)[:-2])
        for held_site, _n in stack:
            if held_site == site:
                continue
            with self._mu:
                back = self._find_path(site, held_site)
                self.edges.setdefault(held_site, set()).add(site)
                key = (held_site, site)
                self.edge_witness.setdefault(key, new_witness)
                if back is not None:
                    cycle = [held_site] + back
                    prior = self.edge_witness.get(
                        (back[0], back[1]) if len(back) > 1 else key, "")
                    v = {
                        "kind": "lock-order-cycle",
                        "cycle": cycle,
                        "thread": threading.current_thread().name,
                        "witness_new": new_witness,
                        "witness_prior": prior,
                    }
                    self.violations.append(v)
                    if self.raise_on_violation:
                        raise LockOrderViolation(render_violation(v))
        stack.append((site, 1))

    def record_release(self, site: str) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] == site:
                del stack[i]
                return

    def check_blocking(self, what: str, exempt_site: str | None = None) -> None:
        held = [s for s in self.held_sites() if s != exempt_site]
        if not held:
            return
        v = {
            "kind": "held-across-blocking",
            "blocking": what,
            "held": held,
            "thread": threading.current_thread().name,
            "witness_new": "".join(traceback.format_stack(limit=12)[:-2]),
            "witness_prior": "",
        }
        with self._mu:
            self.violations.append(v)
        if self.raise_on_violation:
            raise HeldAcrossBlocking(render_violation(v))

    def reset(self) -> None:
        with self._mu:
            self.edges.clear()
            self.edge_witness.clear()
            self.violations.clear()


def render_violation(v: dict) -> str:
    lines = [f"[lock-sanitizer] {v['kind']} on thread {v['thread']}"]
    if v["kind"] == "lock-order-cycle":
        lines.append("  cycle: " + " -> ".join(v["cycle"]))
    else:
        lines.append(f"  blocking op: {v['blocking']}")
        lines.append("  held locks: " + ", ".join(v["held"]))
    if v.get("witness_new"):
        lines.append("  acquisition trace:")
        lines.extend("    " + ln for ln in v["witness_new"].rstrip().splitlines())
    if v.get("witness_prior"):
        lines.append("  prior conflicting trace:")
        lines.extend("    " + ln for ln in v["witness_prior"].rstrip().splitlines())
    return "\n".join(lines)


STATE = SanitizerState()


# --------------------------------------------------------------------------
# Wrappers
# --------------------------------------------------------------------------

class SanitizedLock:
    _reentrant = False

    def __init__(self, state: SanitizerState | None = None,
                 site: str | None = None) -> None:
        self._state = state or STATE
        self._site = site or _creation_site()
        self._inner = _REAL_RLOCK() if self._reentrant else _REAL_LOCK()
        self._tls = threading.local()

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            if self._depth() == 0:
                try:
                    self._state.record_acquire(self._site)
                except BaseException:
                    self._inner.release()
                    raise
            self._tls.depth = self._depth() + 1
        return ok

    def release(self) -> None:
        d = self._depth()
        self._inner.release()
        if d == 1:
            self._state.record_release(self._site)
        self._tls.depth = max(0, d - 1)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._depth() > 0 or (not self._reentrant and self._inner.locked())

    # Condition() introspects these on the lock it's handed
    def _release_save(self):
        d = self._depth()
        self._tls.depth = 0
        if d:
            self._state.record_release(self._site)
        if self._reentrant:
            saved = self._inner._release_save()
            return (saved, d)
        self._inner.release()
        return (None, d)

    def _acquire_restore(self, saved) -> None:
        inner_saved, d = saved
        if self._reentrant:
            self._inner._acquire_restore(inner_saved)
        else:
            self._inner.acquire()
        if d:
            self._state.record_acquire(self._site)
        self._tls.depth = d

    def _is_owned(self) -> bool:
        return self._depth() > 0


class SanitizedRLock(SanitizedLock):
    _reentrant = True


class SanitizedCondition:
    def __init__(self, lock=None, state: SanitizerState | None = None,
                 site: str | None = None) -> None:
        self._state = state or STATE
        self._site = site or _creation_site()
        if lock is None:
            lock = SanitizedRLock(state=self._state, site=self._site)
        self._lock = lock
        self._inner = _REAL_CONDITION(lock)

    @property
    def _sanitized_site(self) -> str:
        return getattr(self._lock, "_site", self._site)

    def acquire(self, *a, **kw):
        return self._lock.acquire(*a, **kw)

    def release(self) -> None:
        self._lock.release()

    def __enter__(self):
        self._lock.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self._lock.release()

    def wait(self, timeout: float | None = None) -> bool:
        self._state.check_blocking(
            f"Condition.wait at {self._site}", exempt_site=self._sanitized_site)
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: float | None = None):
        self._state.check_blocking(
            f"Condition.wait_for at {self._site}", exempt_site=self._sanitized_site)
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1) -> None:
        self._inner.notify(n)

    def notify_all(self) -> None:
        self._inner.notify_all()


# --------------------------------------------------------------------------
# Enable / disable
# --------------------------------------------------------------------------

_enabled = False


def _should_sanitize() -> bool:
    # frame 0=_caller_module, 1=_should_sanitize, 2=factory, 3=call site
    mod = _caller_module(2)
    return mod == "repro" or mod.startswith("repro.")


def _lock_factory():
    if _should_sanitize():
        return SanitizedLock(site=_creation_site())
    return _REAL_LOCK()


def _rlock_factory():
    if _should_sanitize():
        return SanitizedRLock(site=_creation_site())
    return _REAL_RLOCK()


def _condition_factory(lock=None):
    if _should_sanitize():
        return SanitizedCondition(lock, site=_creation_site())
    return _REAL_CONDITION(lock)


def _sanitized_sleep(seconds: float) -> None:
    if STATE.held_sites():
        STATE.check_blocking(f"time.sleep({seconds!r})")
    _REAL_SLEEP(seconds)


def enable() -> None:
    """Install the sanitized primitives (idempotent).  Only ``repro.*``
    call sites get wrapped; everyone else sees the real classes."""
    global _enabled
    if _enabled:
        return
    threading.Lock = _lock_factory
    threading.RLock = _rlock_factory
    threading.Condition = _condition_factory
    time.sleep = _sanitized_sleep
    _enabled = True


def disable() -> None:
    global _enabled
    if not _enabled:
        return
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    time.sleep = _REAL_SLEEP
    _enabled = False


def enabled() -> bool:
    return _enabled


def enabled_by_env() -> bool:
    return os.environ.get("REPRO_LOCK_SANITIZER", "") not in ("", "0")
