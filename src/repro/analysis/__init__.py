"""Repo-specific static analysis + runtime lock sanitizer.

Static passes (pure stdlib, no jax needed):

- :func:`analyze_lockgraph` — lock discovery, acquisition-order edges from
  ``with`` nesting + call-graph propagation, lock-order cycle and
  blocking-while-locked reporting.
- :func:`analyze_lint` — REP001–REP005 repo-invariant rules.

Runtime sanitizer (``REPRO_LOCK_SANITIZER=1``): :mod:`.sanitizer` wraps
``threading.Lock/RLock/Condition`` for ``repro.*`` call sites and detects
real acquisition-order cycles and held-across-blocking at test time.

``scripts/analyze.py`` is the CLI entry point; accepted findings live in
``analysis-baseline.json`` at the repo root.
"""
from .findings import Finding, dedup
from .lint import analyze_lint
from .lockgraph import analyze_lockgraph

__all__ = [
    "Finding",
    "dedup",
    "analyze_lint",
    "analyze_lockgraph",
    "run_all",
]


def run_all(root: str) -> list[Finding]:
    """Both static passes over ``root``, deduped and stably ordered."""
    return dedup(analyze_lockgraph(root) + analyze_lint(root))
