"""Finding model shared by the static passes and the analyze CLI.

A finding's ``key`` deliberately excludes the line number: baselines pin
*what* was accepted (rule, file, symbol, discriminating detail), not where
it happened to sit in the file, so unrelated edits above a baselined
finding never churn the baseline.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str        # "LOCK-ORDER" | "LOCK-BLOCKING" | "REP001".."REP005"
    path: str        # repo-relative posix path
    line: int        # 1-based; informational only (not part of the key)
    symbol: str      # qualified symbol ("Class.method", "func", "<module>")
    message: str     # human-readable description
    detail: str = ""  # stable discriminator (no line numbers)

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}:{self.detail or '-'}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key
        return d

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.symbol}: {self.message}"


def dedup(findings: list[Finding]) -> list[Finding]:
    """Drop key-duplicates, keeping the first (lowest-line) occurrence."""
    seen: set[str] = set()
    out: list[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        if f.key not in seen:
            seen.add(f.key)
            out.append(f)
    return out
