"""Lock-order and blocking-while-locked static analysis.

Walks every module under a package root (normally ``src/repro``) and:

1. **Discovers locks** — ``self.attr = threading.Lock()/RLock()/Condition()``
   assignments inside class bodies and module-level ``NAME = threading.Lock()``
   assignments.  ``threading.Condition(self._lock)`` aliases the condition
   attribute to the underlying lock's identity, so re-entry through either
   name is not a self-edge.
2. **Summarises each function** — a lexical walk tracks the set of held
   locks through ``with`` nesting and records three kinds of events:
   lock *acquisitions* (producing order edges ``held -> acquired``),
   *blocking operations* (``time.sleep``, file/page I/O, ``Future.result``,
   ``cv.wait`` on a lock other than every currently-held one,
   ``WSCache.fetch``, thread-pool ``with``-exit joins), and *calls* into
   other functions of the package.
3. **Propagates summaries over call edges** — a fixpoint computes, for each
   function, the transitive set of locks it may acquire and blocking ops it
   may perform, so ``with rec.lock: self._force_reclaim(...)`` sees the
   ``Future.result`` buried two calls down.
4. **Reports** — ``LOCK-ORDER`` findings for cycles in the acquisition-order
   graph (Tarjan SCC over the union of all order edges) and
   ``LOCK-BLOCKING`` findings for blocking ops reachable while at least one
   non-exempt lock is held.

The pass is deliberately heuristic (no type checker): receivers resolve via
parameter annotations, ``self.attr = ClassName(...)`` constructor
inference, ``dict[str, T]`` element types, and a small local-variable
type environment.  Unresolvable receivers are skipped rather than guessed,
so findings err toward precision; the seeded-violation fixtures in
``tests/`` pin the recall we rely on.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Optional, Union

from .findings import Finding, dedup

LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}

# Dotted-path calls that always block.
BLOCKING_DOTTED = {
    "time.sleep": "time.sleep",
    "os.preadv": "file I/O (os.preadv)",
    "os.pread": "file I/O (os.pread)",
    "os.read": "file I/O (os.read)",
    "os.fsync": "file I/O (os.fsync)",
    "np.load": "file I/O (np.load)",
    "numpy.load": "file I/O (np.load)",
    "np.save": "file I/O (np.save)",
    "numpy.save": "file I/O (np.save)",
}
# Bare-name calls that always block.
BLOCKING_NAMES = {
    "open": "file I/O (open)",
    "connect_handshake": "connection handshake",
}
# Method names (last dotted segment) that block regardless of receiver.
BLOCKING_METHODS = {
    "result": "Future.result",
    "read_page": "page-source I/O",
    "read_span": "page-source I/O",
    "fetch": "single-flight fetch",
    "acquire_throttled": "throttled acquire",
}
WAIT_METHODS = {"wait", "wait_for"}


# --------------------------------------------------------------------------
# Discovery data model
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ClassInfo:
    name: str
    module: str            # repo-relative path
    bases: list[str]
    methods: dict[str, ast.FunctionDef] = dataclasses.field(default_factory=dict)
    # attr -> lock id (aliases resolved), e.g. {"_lock": "InstanceArena._lock"}
    lock_attrs: dict[str, str] = dataclasses.field(default_factory=dict)
    # attr -> inferred type: "ClassName" | ("dict", "V") | ("list", "V")
    attr_types: dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FuncInfo:
    key: str               # "Class.method" or "module.py:func"
    module: str
    node: ast.FunctionDef
    cls: Optional[ClassInfo]
    # local events (populated by the summary walk)
    acquires: list = dataclasses.field(default_factory=list)   # (lock, held, line)
    blocking: list = dataclasses.field(default_factory=list)   # (kind, held, exempt, line)
    calls: list = dataclasses.field(default_factory=list)      # (callee_key, held, line)
    # transitive closures (fixpoint)
    acq_closure: set = dataclasses.field(default_factory=set)      # lock ids
    blk_closure: set = dataclasses.field(default_factory=set)      # (kind, origin_key, exempt)


class Registry:
    def __init__(self) -> None:
        self.classes: dict[str, ClassInfo] = {}
        self.funcs: dict[str, FuncInfo] = {}
        self.module_locks: dict[str, str] = {}      # "path:NAME" -> lock id
        self.lock_kinds: dict[str, str] = {}        # lock id -> factory name
        # attr name -> lock ids sharing it (for unique-attr fallback)
        self.attr_index: dict[str, set[str]] = {}
        self.imports: dict[str, dict[str, str]] = {}  # module -> local name -> symbol

    def method_of(self, cls: ClassInfo, name: str) -> Optional[FuncInfo]:
        seen = set()
        cur: Optional[ClassInfo] = cls
        while cur and cur.name not in seen:
            seen.add(cur.name)
            fi = self.funcs.get(f"{cur.name}.{name}")
            if fi is not None:
                return fi
            cur = next((self.classes[b] for b in cur.bases if b in self.classes), None)
        return None

    def lock_attr_of(self, cls: ClassInfo, attr: str) -> Optional[str]:
        seen = set()
        cur: Optional[ClassInfo] = cls
        while cur and cur.name not in seen:
            seen.add(cur.name)
            if attr in cur.lock_attrs:
                return cur.lock_attrs[attr]
            cur = next((self.classes[b] for b in cur.bases if b in self.classes), None)
        return None

    def attr_type_of(self, cls: ClassInfo, attr: str):
        seen = set()
        cur: Optional[ClassInfo] = cls
        while cur and cur.name not in seen:
            seen.add(cur.name)
            if attr in cur.attr_types:
                return cur.attr_types[attr]
            cur = next((self.classes[b] for b in cur.bases if b in self.classes), None)
        return None


# --------------------------------------------------------------------------
# Small AST helpers
# --------------------------------------------------------------------------

def _dotted(node: ast.expr) -> Optional[str]:
    """'time.sleep' for Attribute chains, 'open' for Name, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_threading_factory(call: ast.Call) -> Optional[str]:
    path = _dotted(call.func)
    if path is None:
        return None
    last = path.rsplit(".", 1)[-1]
    if last in LOCK_FACTORIES and (path == last or path.startswith("threading.")):
        return last
    return None


def _ann_type(ann: Optional[ast.expr]):
    """Annotation -> 'ClassName' | ('dict', V) | ('list', V) | None."""
    if ann is None:
        return None
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value.split("[")[0].split(".")[-1] or None
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Subscript):
        base = _ann_type(ann.value)
        if base in ("dict", "Dict"):
            elts = ann.slice.elts if isinstance(ann.slice, ast.Tuple) else [ann.slice]
            if len(elts) == 2:
                return ("dict", _ann_type(elts[1]))
        if base in ("list", "List", "set", "Set", "deque", "Optional", "Sequence",
                    "Iterable", "Iterator"):
            inner = ann.slice.elts[0] if isinstance(ann.slice, ast.Tuple) else ann.slice
            if base == "Optional":
                return _ann_type(inner)
            return ("list", _ann_type(inner))
    return None


def _elem(t):
    return t[1] if isinstance(t, tuple) else None


# --------------------------------------------------------------------------
# Pass 1: discovery
# --------------------------------------------------------------------------

def _discover(tree: ast.Module, path: str, reg: Registry) -> None:
    imports: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                imports[alias.asname or alias.name] = alias.name
    reg.imports[path] = imports

    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            bases = [b.id if isinstance(b, ast.Name) else getattr(b, "attr", "")
                     for b in node.bases]
            ci = ClassInfo(node.name, path, bases)
            reg.classes[node.name] = ci
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    ci.methods[item.name] = item
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            kind = _is_threading_factory(node.value)
            if kind and len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                lock_id = f"{path}:{name}"
                reg.module_locks[lock_id] = lock_id
                reg.lock_kinds[lock_id] = kind
                reg.attr_index.setdefault(name, set()).add(lock_id)

    # second sweep: per-class attr discovery needs the full class table
    for node in tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        ci = reg.classes[node.name]
        # dataclass-style annotated fields contribute attr types
        for item in node.body:
            if isinstance(item, ast.AnnAssign) and isinstance(item.target, ast.Name):
                t = _ann_type(item.annotation)
                if t:
                    ci.attr_types[item.target.id] = t
        for meth in ci.methods.values():
            for stmt in ast.walk(meth):
                tgt = None
                val = None
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt, val = stmt.targets[0], stmt.value
                elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    tgt, val = stmt.target, stmt.value
                    if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        t = _ann_type(stmt.annotation)
                        if t:
                            ci.attr_types.setdefault(tgt.attr, t)
                if not (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self" and isinstance(val, ast.Call)):
                    continue
                attr = tgt.attr
                kind = _is_threading_factory(val)
                if kind == "Condition" and val.args:
                    # Condition(self._x): alias to the underlying lock
                    under = val.args[0]
                    if (isinstance(under, ast.Attribute)
                            and isinstance(under.value, ast.Name)
                            and under.value.id == "self"):
                        target_id = ci.lock_attrs.get(
                            under.attr, f"{ci.name}.{under.attr}")
                        ci.lock_attrs[attr] = target_id
                        reg.attr_index.setdefault(attr, set()).add(target_id)
                        continue
                if kind:
                    lock_id = f"{ci.name}.{attr}"
                    ci.lock_attrs.setdefault(attr, lock_id)
                    reg.lock_kinds[lock_id] = kind
                    reg.attr_index.setdefault(attr, set()).add(lock_id)
                    continue
                ctor = _dotted(val.func)
                if ctor:
                    last = ctor.rsplit(".", 1)[-1]
                    ci.attr_types.setdefault(attr, last)


# --------------------------------------------------------------------------
# Pass 2: per-function summaries
# --------------------------------------------------------------------------

class _FuncWalker:
    def __init__(self, reg: Registry, fi: FuncInfo) -> None:
        self.reg = reg
        self.fi = fi
        self.env: dict[str, object] = {}
        args = fi.node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            t = _ann_type(a.annotation)
            if t:
                self.env[a.arg] = t

    # -- type / lock resolution ------------------------------------------

    def resolve_type(self, node: ast.expr):
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Attribute):
            base = self.resolve_type(node.value)
            if isinstance(node.value, ast.Name) and node.value.id == "self" and self.fi.cls:
                return self.reg.attr_type_of(self.fi.cls, node.attr)
            if isinstance(base, str) and base in self.reg.classes:
                return self.reg.attr_type_of(self.reg.classes[base], node.attr)
            return None
        if isinstance(node, ast.Subscript):
            return _elem(self.resolve_type(node.value))
        if isinstance(node, ast.Call):
            path = _dotted(node.func)
            if path:
                last = path.rsplit(".", 1)[-1]
                if last in self.reg.classes and (path == last or "." not in path):
                    return last
                if last == "list" and node.args:
                    t = self.resolve_type(node.args[0])
                    return t if isinstance(t, tuple) else ("list", t)
                if last in ("values", "get", "pop", "popleft", "popitem", "setdefault"):
                    recv = node.func.value if isinstance(node.func, ast.Attribute) else None
                    if recv is not None:
                        rt = self.resolve_type(recv)
                        e = _elem(rt)
                        if last == "values":
                            return ("list", e)
                        return e
            return None
        return None

    def resolve_lock(self, node: ast.expr) -> Optional[str]:
        """Resolve an expression to a lock identity, or None."""
        if isinstance(node, ast.Attribute):
            recv = node.value
            if isinstance(recv, ast.Name) and recv.id == "self" and self.fi.cls:
                return self.reg.lock_attr_of(self.fi.cls, node.attr)
            rt = self.resolve_type(recv)
            if isinstance(rt, str) and rt in self.reg.classes:
                return self.reg.lock_attr_of(self.reg.classes[rt], node.attr)
            # unique-attr fallback: exactly one lock in the package has
            # this attribute name
            ids = self.reg.attr_index.get(node.attr, set())
            if len(ids) == 1:
                return next(iter(ids))
            return None
        if isinstance(node, ast.Name):
            ids = {lid for lid in self.reg.module_locks
                   if lid.endswith(f":{node.id}")}
            own = f"{self.fi.module}:{node.id}"
            if own in ids:
                return own
            if len(ids) == 1:
                return next(iter(ids))
            if node.id in self.env:
                t = self.env[node.id]
                if isinstance(t, str) and t in self.reg.lock_kinds:
                    return t
        return None

    # -- call resolution --------------------------------------------------

    def resolve_call(self, call: ast.Call) -> Optional[str]:
        func = call.func
        if isinstance(func, ast.Attribute):
            recv = func.value
            if isinstance(recv, ast.Name) and recv.id == "self" and self.fi.cls:
                m = self.reg.method_of(self.fi.cls, func.attr)
                return m.key if m else None
            rt = self.resolve_type(recv)
            if isinstance(rt, str) and rt in self.reg.classes:
                m = self.reg.method_of(self.reg.classes[rt], func.attr)
                return m.key if m else None
            return None
        if isinstance(func, ast.Name):
            name = func.id
            if name in self.reg.classes:
                m = self.reg.method_of(self.reg.classes[name], "__init__")
                return m.key if m else None
            key = f"{self.fi.module}:{name}"
            if key in self.reg.funcs:
                return key
            target = self.reg.imports.get(self.fi.module, {}).get(name)
            if target:
                for k in self.reg.funcs:
                    if k.endswith(f":{target}"):
                        return k
                if target in self.reg.classes:
                    m = self.reg.method_of(self.reg.classes[target], "__init__")
                    return m.key if m else None
        return None

    # -- the walk ---------------------------------------------------------

    def walk(self) -> None:
        for stmt in self.fi.node.body:
            self._visit(stmt, ())

    def _visit(self, node: ast.AST, held: tuple) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                             ast.ClassDef)):
            return  # nested scopes get their own (unresolved) summaries
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            self._infer_assign(node)
        if isinstance(node, ast.For):
            t = self.resolve_type(node.iter)
            if isinstance(node.target, ast.Name) and _elem(t):
                self.env[node.target.id] = _elem(t)
        if isinstance(node, (ast.With, ast.AsyncWith)):
            new_held = list(held)
            for item in node.items:
                ctx = item.context_expr
                lock = None
                if isinstance(ctx, ast.Call):
                    path = _dotted(ctx.func) or ""
                    last = path.rsplit(".", 1)[-1]
                    if last == "ThreadPoolExecutor":
                        # with-exit joins the workers: blocking
                        self.fi.blocking.append(
                            ("thread-pool join at with-exit", tuple(new_held),
                             frozenset(), ctx.lineno))
                    self._visit(ctx, tuple(new_held))
                else:
                    lock = self.resolve_lock(ctx)
                if lock is not None and lock not in new_held:
                    self.fi.acquires.append((lock, tuple(new_held), ctx.lineno))
                    new_held.append(lock)
                if item.optional_vars is not None and lock is None \
                        and isinstance(item.optional_vars, ast.Name):
                    t = self.resolve_type(ctx)
                    if t:
                        self.env[item.optional_vars.id] = t
            for stmt in node.body:
                self._visit(stmt, tuple(new_held))
            return
        if isinstance(node, ast.Call):
            self._visit_call(node, held)
            for child in ast.iter_child_nodes(node):
                self._visit(child, held)
            return
        for child in ast.iter_child_nodes(node):
            self._visit(child, held)

    def _infer_assign(self, node: Union[ast.Assign, ast.AnnAssign]) -> None:
        tgt = node.targets[0] if isinstance(node, ast.Assign) else node.target
        if not isinstance(tgt, ast.Name):
            return
        t = None
        if isinstance(node, ast.AnnAssign):
            t = _ann_type(node.annotation)
        if t is None and node.value is not None:
            t = self.resolve_type(node.value)
        if t:
            self.env[tgt.id] = t

    def _visit_call(self, call: ast.Call, held: tuple) -> None:
        path = _dotted(call.func)
        if path is None:
            return
        last = path.rsplit(".", 1)[-1]

        if path in BLOCKING_DOTTED:
            self.fi.blocking.append(
                (BLOCKING_DOTTED[path], held, frozenset(), call.lineno))
            return
        if path in BLOCKING_NAMES:
            self.fi.blocking.append(
                (BLOCKING_NAMES[path], held, frozenset(), call.lineno))
            return
        if last in WAIT_METHODS and isinstance(call.func, ast.Attribute):
            lock = self.resolve_lock(call.func.value)
            if lock is not None:
                # waiting on a condition releases *its own* lock only
                self.fi.blocking.append(
                    (f"cv.wait on {lock}", held, frozenset({lock}), call.lineno))
            return
        if last in BLOCKING_METHODS and isinstance(call.func, ast.Attribute):
            # skip str.join-style literals
            if not isinstance(call.func.value, ast.Constant):
                self.fi.blocking.append(
                    (BLOCKING_METHODS[last], held, frozenset(), call.lineno))
            return

        callee = self.resolve_call(call)
        if callee is not None and callee != self.fi.key:
            self.fi.calls.append((callee, held, call.lineno))


# --------------------------------------------------------------------------
# Pass 2.5: cross-class attribute-type fixpoint
# --------------------------------------------------------------------------

def _infer_attr_types(reg: Registry) -> None:
    """Propagate attr types through assignments like
    ``self._tail = pipe.tail`` (param-annotation + other classes' attr
    types), iterated to fixpoint so discovery order doesn't matter."""
    method_fis = [fi for fi in reg.funcs.values() if fi.cls is not None]
    for _round in range(5):
        changed = False
        for fi in method_fis:
            w = _FuncWalker(reg, fi)
            for stmt in ast.walk(fi.node):
                if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1):
                    continue
                tgt = stmt.targets[0]
                if not (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    continue
                if tgt.attr in fi.cls.attr_types or tgt.attr in fi.cls.lock_attrs:
                    continue
                t = w.resolve_type(stmt.value)
                if t:
                    fi.cls.attr_types[tgt.attr] = t
                    changed = True
        if not changed:
            break


# --------------------------------------------------------------------------
# Pass 3: fixpoint over call edges
# --------------------------------------------------------------------------

def _fixpoint(reg: Registry) -> None:
    for fi in reg.funcs.values():
        fi.acq_closure = {lock for lock, _held, _ln in fi.acquires}
        fi.blk_closure = {(kind, fi.key, exempt)
                          for kind, _held, exempt, _ln in fi.blocking}
    changed = True
    while changed:
        changed = False
        for fi in reg.funcs.values():
            for callee_key, _held, _ln in fi.calls:
                callee = reg.funcs.get(callee_key)
                if callee is None:
                    continue
                if not callee.acq_closure <= fi.acq_closure:
                    fi.acq_closure |= callee.acq_closure
                    changed = True
                if not callee.blk_closure <= fi.blk_closure:
                    fi.blk_closure |= callee.blk_closure
                    changed = True


# --------------------------------------------------------------------------
# Pass 4: findings
# --------------------------------------------------------------------------

def _tarjan_sccs(nodes: set, edges: dict) -> list:
    index: dict = {}
    low: dict = {}
    on_stack: set = set()
    stack: list = []
    sccs: list = []
    counter = [0]

    def strongconnect(v):
        work = [(v, iter(sorted(edges.get(v, ()))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(edges.get(w, ())))))
                    advanced = True
                    break
                elif w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                sccs.append(scc)

    for v in sorted(nodes):
        if v not in index:
            strongconnect(v)
    return sccs


def analyze_lockgraph(root: str) -> list[Finding]:
    """Run the full pass over ``root`` (a package directory) and return
    LOCK-ORDER / LOCK-BLOCKING findings."""
    reg = Registry()
    modules: dict[str, ast.Module] = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            try:
                with open(full, "r", encoding="utf-8") as fh:
                    modules[rel] = ast.parse(fh.read(), filename=rel)
            except SyntaxError:
                continue

    for rel, tree in modules.items():
        _discover(tree, rel, reg)

    # function table
    for rel, tree in modules.items():
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                key = f"{rel}:{node.name}"
                reg.funcs[key] = FuncInfo(key, rel, node, None)
    for ci in reg.classes.values():
        for name, node in ci.methods.items():
            key = f"{ci.name}.{name}"
            reg.funcs[key] = FuncInfo(key, ci.module, node, ci)

    _infer_attr_types(reg)

    for fi in reg.funcs.values():
        _FuncWalker(reg, fi).walk()

    _fixpoint(reg)

    findings: list[Finding] = []

    # ---- order edges + held-across-blocking, local and via calls --------
    edges: dict = {}
    witness: dict = {}  # (a, b) -> (module, line, func_key)

    def add_edge(a, b, module, line, func_key):
        if a == b:
            return
        edges.setdefault(a, set()).add(b)
        witness.setdefault((a, b), (module, line, func_key))

    for fi in reg.funcs.values():
        for lock, held, line in fi.acquires:
            for h in held:
                add_edge(h, lock, fi.module, line, fi.key)
        for callee_key, held, line in fi.calls:
            if not held:
                continue
            callee = reg.funcs.get(callee_key)
            if callee is None:
                continue
            for lock in callee.acq_closure:
                for h in held:
                    add_edge(h, lock, fi.module, line, fi.key)
            for kind, origin, exempt in callee.blk_closure:
                bad = [h for h in held if h not in exempt]
                if bad:
                    via = f" via {origin}" if origin != fi.key else ""
                    findings.append(Finding(
                        rule="LOCK-BLOCKING", path=fi.module, line=line,
                        symbol=fi.key,
                        message=(f"{kind}{via} while holding "
                                 f"{', '.join(sorted(bad))}"),
                        detail=f"{kind}|{origin}|{'+'.join(sorted(bad))}"))
        for kind, held, exempt, line in fi.blocking:
            bad = [h for h in held if h not in exempt]
            if bad:
                findings.append(Finding(
                    rule="LOCK-BLOCKING", path=fi.module, line=line,
                    symbol=fi.key,
                    message=f"{kind} while holding {', '.join(sorted(bad))}",
                    detail=f"{kind}|{fi.key}|{'+'.join(sorted(bad))}"))

    # ---- cycles ---------------------------------------------------------
    nodes = set(edges) | {b for bs in edges.values() for b in bs}
    for scc in _tarjan_sccs(nodes, edges):
        cyclic = len(scc) > 1 or (len(scc) == 1 and scc[0] in edges.get(scc[0], ()))
        if not cyclic:
            continue
        cyc = sorted(scc)
        sites = []
        for a in cyc:
            for b in cyc:
                w = witness.get((a, b))
                if w:
                    sites.append(f"{a}->{b} at {w[0]}:{w[1]} ({w[2]})")
        mod, line, func = witness.get(
            (cyc[0], cyc[1] if len(cyc) > 1 else cyc[0]),
            (next(iter(sites), "?:0 (?)").split(" at ")[-1].split(":")[0], 0, "?")
        )[:3] if witness else ("?", 0, "?")
        findings.append(Finding(
            rule="LOCK-ORDER", path=mod, line=line, symbol=func,
            message=("lock-order cycle: " + " <-> ".join(cyc)
                     + "; witnesses: " + "; ".join(sites)),
            detail="+".join(cyc)))

    return dedup(findings)
