from . import checkpoint, optimizer, train_loop
from .optimizer import OptConfig
from .train_loop import SimulatedPreemption, Trainer, TrainLoopConfig
