"""Fault-tolerant training loop.

* jitted train_step (remat-able, grad-accum-able),
* async double-buffered checkpoints through the snapshot substrate,
* restart path with REAP-accelerated restore,
* deterministic data order keyed by (step, rank) => exactly-once semantics
  across restarts,
* preemption simulation hook for the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..data.pipeline import PrefetchLoader, TokenDataset
from ..launch import steps as steps_lib
from . import optimizer as opt_lib
from .checkpoint import AsyncCheckpointer, restore_checkpoint


class SimulatedPreemption(Exception):
    """Raised by the preemption hook to model a node loss."""


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 50
    checkpoint_every: int = 10
    batch_size: int = 4
    seq_len: int = 64
    remat: bool = False
    restore_mode: str = "reap"  # lazy | reap
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: ModelConfig, opt: opt_lib.OptConfig,
                 loop: TrainLoopConfig, corpus_path: str, ckpt_dir: str,
                 *, preempt_at: int | None = None):
        self.cfg, self.opt, self.loop = cfg, opt, loop
        self.dataset = TokenDataset(corpus_path, loop.seq_len)
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.preempt_at = preempt_at
        self.step_fn = jax.jit(steps_lib.build_train_step(
            cfg, opt, remat=loop.remat), donate_argnums=(0, 1))
        self.restore_stats: dict | None = None

    def _fresh_state(self, seed: int = 0):
        params = steps_lib.init_params(self.cfg, jax.random.key(seed))
        return params, opt_lib.init_state(params, self.opt)

    def _resume_or_init(self):
        base = self.ckpt.latest()
        if base is None:
            params, opt_state = self._fresh_state()
            return params, opt_state, 0
        params, opt_state = self._fresh_state()
        params, opt_state, step, stats = restore_checkpoint(
            base, params, opt_state, mode=self.loop.restore_mode)
        self.restore_stats = stats
        return params, opt_state, step

    def run(self) -> dict:
        params, opt_state, start = self._resume_or_init()
        losses: list[float] = []
        loader = PrefetchLoader(self.dataset, self.loop.batch_size,
                                start_step=start)
        t0 = time.perf_counter()
        try:
            step = start
            while step < self.loop.total_steps:
                got_step, tokens = next(loader)
                assert got_step == step, (got_step, step)
                batch = self._make_batch(tokens)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                step += 1
                if step % self.loop.checkpoint_every == 0:
                    self.ckpt.save(params, opt_state, step)
                if self.preempt_at is not None and step >= self.preempt_at:
                    self.preempt_at = None
                    raise SimulatedPreemption(f"preempted at step {step}")
        finally:
            loader.close()
            self.ckpt.wait()
        return {
            "final_step": step,
            "losses": losses,
            "seconds": time.perf_counter() - t0,
            "restore_stats": self.restore_stats,
        }

    def _make_batch(self, tokens) -> dict:
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            b = tokens.shape[0]
            batch["patch_embeds"] = jnp.zeros(
                (b, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        elif self.cfg.family == "encdec":
            b, s = tokens.shape
            batch["frames"] = jnp.zeros(
                (b, max(s // self.cfg.frame_stride, 1), self.cfg.d_model),
                jnp.bfloat16)
        return batch
