"""Checkpointing through the snapshot substrate.

A training checkpoint is a guest-memory file whose tensors are
``params/...`` (serving dtype), ``opt/...`` (f32 moments) and ``meta/step``.
Restore paths:

  * ``lazy``  -- page-by-page serial faults in tree order: the vanilla-
                 snapshot baseline applied to training restart.
  * ``reap``  -- single large read + eager install (the whole file is the
                 stable working set of a restart -- REAP's ideal case).
  * ``serve`` -- REAP record/prefetch of the *params-only* working set: the
                 same checkpoint deploys to serving without paying for
                 optimizer state (the Fig. 4 footprint gap, applied to
                 checkpoints).

Also provides **elastic re-shard restore**: the arena layout is
mesh-agnostic, so any host can read exactly the byte ranges of its shards
under a *new* mesh (leading-axis row ranges per device).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.arena import ArenaLayout, GuestMemoryFile, InstanceArena, PageSource
from ..nn import spec as nnspec


def _tree_arrays(prefix: str, tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in _np_leaves(tree):
        out[f"{prefix}/{path}"] = leaf
    return out


def _np_leaves(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _np_leaves(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _np_leaves(v, f"{prefix}{i}/")
    else:
        yield prefix.rstrip("/"), np.asarray(tree)


def save_checkpoint(base: str, params, opt_state, step: int) -> str:
    """Write <base>.mem/.manifest.json atomically; returns base."""
    arrays = _tree_arrays("params", params)
    arrays.update(_tree_arrays("opt", opt_state))
    arrays["meta/step"] = np.asarray([step], np.int64)
    tensors = [(p, a.shape, str(a.dtype), "serve" if p.startswith("params") else "boot")
               for p, a in arrays.items()]
    layout = ArenaLayout.build(tensors)
    tmp = base + ".tmp"
    GuestMemoryFile.create(tmp, layout, arrays)
    os.replace(tmp + ".mem", base + ".mem")
    os.replace(tmp + ".manifest.json", base + ".manifest.json")
    return base


class AsyncCheckpointer:
    """Double-buffered async save (fault-tolerance substrate): snapshots are
    staged to host and written by a background thread so the train loop only
    blocks for the host copy."""

    def __init__(self, dir_: str, keep: int = 2):
        self.dir = dir_
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(dir_, exist_ok=True)

    def save(self, params, opt_state, step: int) -> None:
        self.wait()
        host_p = jax.tree.map(np.asarray, params)   # stage to host
        host_o = jax.tree.map(np.asarray, opt_state)

        def work():
            base = os.path.join(self.dir, f"ckpt_{step:08d}")
            save_checkpoint(base, host_p, host_o, step)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        bases = sorted(b[:-4] for b in os.listdir(self.dir) if b.endswith(".mem"))
        for b in bases[:-self.keep]:
            for suf in (".mem", ".manifest.json"):
                p = os.path.join(self.dir, b + suf)
                if os.path.exists(p):
                    os.remove(p)

    def latest(self) -> str | None:
        bases = sorted(b[:-4] for b in os.listdir(self.dir) if b.endswith(".mem"))
        return os.path.join(self.dir, bases[-1]) if bases else None


def restore_checkpoint(base: str, params_like, opt_like, *,
                       mode: str = "reap") -> tuple[Any, Any, int, dict]:
    """Restore (params, opt_state, step).  ``mode``: lazy | reap.

    Returns (params, opt_state, step, stats) with stats reporting restore
    I/O time and page counts -- consumed by the restart benchmark.
    """
    gm = GuestMemoryFile.open(base)
    arena = InstanceArena(gm, o_direct=True)
    t0 = time.perf_counter()
    if mode == "reap":
        src = PageSource(gm.mem_path, o_direct=True)
        try:
            data = src.read_span(0, gm.layout.total_bytes)
        finally:
            src.close()
        arena.install_span(range(gm.layout.n_pages), data)
    else:
        for e in gm.layout.entries.values():
            arena.touch_pages(e.pages())
    io_s = time.perf_counter() - t0

    def fill(template, prefix):
        def one(path, leaf):
            arr = arena.tensor(f"{prefix}/{path}", fault=(mode == "lazy"))
            return jnp.asarray(arr).astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
        return _map_with_paths(one, template)

    params = fill(params_like, "params")
    opt_state = fill(opt_like, "opt")
    step = int(arena.tensor("meta/step", fault=(mode == "lazy"))[0])
    stats = {"io_s": io_s, "bytes": gm.layout.total_bytes,
             "n_faults": arena.stats.n_faults,
             "fault_s": arena.stats.fault_seconds}
    arena.close()
    return params, opt_state, step, stats


def _map_with_paths(fn, tree, prefix=""):
    if isinstance(tree, dict):
        return {k: _map_with_paths(fn, v, f"{prefix}{k}/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_with_paths(fn, v, f"{prefix}{i}/")
                          for i, v in enumerate(tree))
    return fn(prefix.rstrip("/"), tree)


def read_shard(base: str, path: str, lo: int, hi: int) -> np.ndarray:
    """Elastic restore: read only rows [lo, hi) of one tensor -- a host
    restoring onto a different mesh reads exactly its shard's byte range."""
    gm = GuestMemoryFile.open(base)
    e = gm.layout.entries[path]
    row_bytes = e.nbytes // e.shape[0]
    src = PageSource(gm.mem_path, o_direct=False)
    try:
        raw = src.read_span(e.offset + lo * row_bytes, (hi - lo) * row_bytes)
    finally:
        src.close()
    arr = np.frombuffer(raw, dtype=np.dtype(e.dtype))
    return arr.reshape((hi - lo,) + e.shape[1:])


def restore_for_mesh(base: str, spec_tree, mesh, rules) -> Any:
    """Elastic re-shard restore: assemble each tensor from per-shard row
    reads for the (possibly different) target mesh.  On this 1-process CPU
    host all shards land in one array; on a real pod each host reads only
    its addressable shards."""
    from ..distributed.sharding import data_axes
    import math
    n_shards = max(1, math.prod(mesh.shape[a] for a in data_axes(mesh)))

    def one(path, s: nnspec.TensorSpec):
        full = f"params/{path}"
        rows = s.shape[0] if s.shape else 1
        if not s.shape or rows < n_shards:
            gm = GuestMemoryFile.open(base)
            e = gm.layout.entries[full]
            src = PageSource(gm.mem_path, o_direct=False)
            try:
                raw = src.read_span(e.offset, e.nbytes)
            finally:
                src.close()
            return jnp.asarray(np.frombuffer(raw, np.dtype(e.dtype)).reshape(e.shape))
        per = rows // n_shards
        parts = [read_shard(base, full, i * per,
                            rows if i == n_shards - 1 else (i + 1) * per)
                 for i in range(n_shards)]
        return jnp.asarray(np.concatenate(parts, axis=0))

    return nnspec.map_leaves(one, spec_tree)
