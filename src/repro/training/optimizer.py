"""Sharded optimizers (no external deps): AdamW and SGD-momentum.

Optimizer state mirrors the parameter tree leaf-for-leaf, so the sharding
rules (and the snapshot arena layout) apply to it unchanged -- which is what
makes REAP-accelerated checkpoint *restart* work: params + opt state are a
100%-stable working set.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..nn.spec import TensorSpec, map_leaves


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def state_specs(param_specs_tree, opt: OptConfig):
    """Spec tree for optimizer state (drives sharding + snapshot layout)."""
    def f32_like(_, s: TensorSpec) -> TensorSpec:
        return TensorSpec(s.shape, jnp.float32, s.axes, "zeros", None)

    if opt.kind == "adamw":
        return {
            "mu": map_leaves(f32_like, param_specs_tree),
            "nu": map_leaves(f32_like, param_specs_tree),
            "count": TensorSpec((), jnp.int32, (), "zeros"),
        }
    if opt.kind == "sgdm":
        return {
            "mu": map_leaves(f32_like, param_specs_tree),
            "count": TensorSpec((), jnp.int32, (), "zeros"),
        }
    raise ValueError(opt.kind)


def init_state(params, opt: OptConfig):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if opt.kind == "adamw":
        return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros),
                "count": jnp.zeros((), jnp.int32)}
    return {"mu": zeros, "count": jnp.zeros((), jnp.int32)}


def lr_at(opt: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(opt.warmup_steps, 1)
    prog = jnp.clip((step - opt.warmup_steps)
                    / jnp.maximum(opt.total_steps - opt.warmup_steps, 1), 0, 1)
    cos = opt.min_lr_ratio + (1 - opt.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return opt.lr * jnp.where(step < opt.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params, grads, state, opt: OptConfig):
    """One optimizer step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
    count = state["count"] + 1
    lr = lr_at(opt, count)

    if opt.kind == "adamw":
        def upd(p, g, m, v):
            m2 = opt.b1 * m + (1 - opt.b1) * g
            v2 = opt.b2 * v + (1 - opt.b2) * jnp.square(g)
            mhat = m2 / (1 - opt.b1 ** count.astype(jnp.float32))
            vhat = v2 / (1 - opt.b2 ** count.astype(jnp.float32))
            step = mhat / (jnp.sqrt(vhat) + opt.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + opt.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), m2, v2
        flat = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"mu": new_m, "nu": new_v, "count": count}
    else:  # sgdm
        def upd(p, g, m):
            m2 = 0.9 * m + g
            p2 = p.astype(jnp.float32) - lr * m2
            return p2.astype(p.dtype), m2
        flat = jax.tree.map(upd, params, grads, state["mu"])
        new_p = jax.tree.map(lambda t: t[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"mu": new_m, "count": count}

    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
