"""Data pipeline: memmap token corpus + background-prefetch loader.

The prefetch thread double-buffers host batches so device compute never
waits on the data path (straggler mitigation at the input layer); shard-
aware slicing gives each data-parallel rank a disjoint stream.
"""
from __future__ import annotations

import os
import queue
import threading

import numpy as np


def synthesize_corpus(path: str, n_tokens: int, vocab: int, seed: int = 0) -> str:
    """Deterministic Zipf-ish synthetic corpus (int32 memmap)."""
    if not os.path.exists(path):
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        toks = rng.choice(vocab, size=n_tokens, p=probs).astype(np.int32)
        tmp = path + ".tmp"
        toks.tofile(tmp)
        os.replace(tmp, path)
    return path


class TokenDataset:
    def __init__(self, path: str, seq_len: int):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.n_seqs = len(self.tokens) // seq_len

    def batch(self, step: int, batch_size: int, *, rank: int = 0,
              world: int = 1) -> np.ndarray:
        """Deterministic batch for (step, rank): restart-safe."""
        idx = (step * batch_size * world + rank * batch_size
               + np.arange(batch_size)) % self.n_seqs
        out = np.empty((batch_size, self.seq_len), np.int32)
        for i, s in enumerate(idx):
            out[i] = self.tokens[s * self.seq_len:(s + 1) * self.seq_len]
        return out


class PrefetchLoader:
    """Background thread keeps ``depth`` batches ready."""

    def __init__(self, dataset: TokenDataset, batch_size: int, *,
                 start_step: int = 0, rank: int = 0, world: int = 1,
                 depth: int = 2):
        self.dataset = dataset
        self.batch_size = batch_size
        self.rank, self.world = rank, world
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = self.dataset.batch(step, self.batch_size, rank=self.rank,
                                   world=self.world)
            try:
                self._q.put((step, b), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
