from .pipeline import PrefetchLoader, TokenDataset, synthesize_corpus
