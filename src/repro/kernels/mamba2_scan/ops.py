"""jit'd wrapper: model layout in ((B, L, H, P) + per-head A), D-residual."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan as _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, D: jax.Array, h0: jax.Array, *,
               chunk: int = 128, interpret: bool = True):
    """x: (Bz, L, H, P); dt: (Bz, L, H); A, D: (H,); B, C: (Bz, L, N);
    h0: (Bz, H, N, P).  Returns (y: (Bz, L, H, P), hT: (Bz, H, N, P))."""
    Bz, L, H, P = x.shape
    N = B.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(Bz * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bz * H, L)
    Af = jnp.tile(A, Bz)
    Bf = jnp.repeat(B, H, axis=0).reshape(Bz, H, L, N).reshape(Bz * H, L, N) \
        if False else jnp.broadcast_to(B[:, None], (Bz, H, L, N)).reshape(Bz * H, L, N)
    Cf = jnp.broadcast_to(C[:, None], (Bz, H, L, N)).reshape(Bz * H, L, N)
    h0f = h0.reshape(Bz * H, N, P)
    y, hT = _kernel(xf, dtf, Af, Bf, Cf, h0f, chunk=chunk, interpret=interpret)
    y = y.reshape(Bz, H, L, P).transpose(0, 2, 1, 3)
    y = y + x * D[None, None, :, None]
    return y, hT.reshape(Bz, H, N, P)
