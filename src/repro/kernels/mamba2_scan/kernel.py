"""Chunked Mamba2 (SSD) scan, Pallas TPU.

TPU adaptation of the CUDA selective-scan: the recurrence
    h_t = exp(A * dt_t) h_{t-1} + dt_t * x_t B_t^T,   y_t = C_t h_t + D x_t
is evaluated chunk-wise -- intra-chunk contributions become (Lc x Lc) MXU
matmuls; the (N x P) state is carried in VMEM scratch across the sequential
chunk dimension of the grid (one flattened batch*head per outer grid step).

Grid: (BH, n_chunks), chunk dim innermost.  D-residual is applied by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hT_ref,
                h_ref, *, Lc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)      # (Lc, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Lc, 1)
    A = a_ref[0, 0]                       # scalar decay rate (negative)
    B = b_ref[0].astype(jnp.float32)      # (Lc, N)
    C = c_ref[0].astype(jnp.float32)      # (Lc, N)
    h = h_ref[...]                        # (N, P)

    la = dt * A                                        # (Lc, 1) log-decay
    cum = jnp.cumsum(la, axis=0)                       # inclusive
    # intra-chunk: M[t, s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s <= t
    diff = cum - cum.T                                 # (Lc, Lc) via broadcast
    mask = jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (Lc, Lc)
    M = cb * decay * dt.T
    y = jax.lax.dot(M, x)                              # (Lc, P)
    # inter-chunk: y_t += exp(cum_t) * C_t @ h
    y = y + jnp.exp(cum) * jax.lax.dot(C, h)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: h' = exp(cum_L) h + sum_s exp(cum_L - cum_s) dt_s B_s^T x_s
    w = jnp.exp(cum[-1:] - cum) * dt                   # (Lc, 1)
    h_new = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        B * w, x, (((0,), (0,)), ((), ())))            # (N, P)
    h_ref[...] = h_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        hT_ref[0] = h_new.astype(hT_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, h0: jax.Array, *, chunk: int = 128,
             interpret: bool = True):
    """x: (BH, L, P); dt: (BH, L); A: (BH,); B, C: (BH, L, N);
    h0: (BH, N, P).  Returns (y: (BH, L, P), hT: (BH, N, P))."""
    BH, L, P = x.shape
    N = B.shape[-1]
    Lc = min(chunk, L)
    assert L % Lc == 0, (L, Lc)

    kernel = functools.partial(_ssd_kernel, Lc=Lc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(BH, L // Lc),
        in_specs=[
            pl.BlockSpec((1, Lc, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, Lc, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, N, P), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, N, P), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], A[:, None], B, C, h0)
    return y, hT
