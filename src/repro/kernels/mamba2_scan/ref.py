"""Recurrent (step-by-step) oracle for the SSD scan.

Also the ground truth for the model-level chunked implementation in
``repro.models.mamba2``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, B, C, h0):
    """x: (BH, L, P); dt: (BH, L); A: (BH,); B, C: (BH, L, N); h0: (BH, N, P)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp           # (BH,P), (BH,), (BH,N), (BH,N)
        decay = jnp.exp(dtt * A)        # (BH,)
        h = h * decay[:, None, None] + (dtt[:, None] * bt)[..., None] * xt[:, None, :]
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    hT, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (xf.transpose(1, 0, 2), dtf.T, Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return y, hT
