"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jit'd public wrapper), <name>/ref.py (pure-jnp
oracle used by tests/test_kernels.py).
"""
from .decode_attention.ops import gqa_decode
from .flash_attention.ops import mha
from .mamba2_scan.ops import mamba2_ssd
from .page_gather.ops import gather_pages, scatter_pages
from .rwkv6_scan.ops import wkv6
