"""page_gather Pallas kernel: trace-driven gather of snapshot pages.

The TPU-native analogue of REAP's WS-file packing (DESIGN.md §3): given a
page table resident in HBM (e.g. a snapshot buffer, an expert bank) and a
recorded trace of page indices, produce the *contiguous* working set in one
pass.  The trace is a scalar-prefetch operand, so the index of every block
is known to the DMA engine *before* the grid step runs -- the hardware
realization of "prefetch pages in trace order".

Block layout: each grid step copies one page (rows of ``page_elems``
elements, padded to the 128-lane requirement by ops.py).  The same kernel
runs in reverse as ``page_scatter`` (eager install of a prefetched WS into
an arena buffer).
"""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, table_ref, out_ref):
    # table_ref block is selected by the index_map below; plain copy here.
    out_ref[...] = table_ref[...]


def page_gather(table: jax.Array, idx: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """out[i, :] = table[idx[i], :].

    table: (n_pages, page_elems) -- any dtype; idx: (n,) int32.
    """
    n = idx.shape[0]
    page_elems = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, page_elems), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, page_elems), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, page_elems), table.dtype),
        interpret=interpret,
    )(idx, table)


def _scatter_kernel(idx_ref, ws_ref, dest_ref, out_ref):
    del dest_ref  # aliased with out: unwritten pages keep arena contents
    out_ref[...] = ws_ref[...]


def page_scatter(ws: jax.Array, idx: jax.Array, dest: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """dest[idx[i], :] = ws[i, :] (in place via aliasing); other pages keep
    their prior contents.

    The eager-install half of the prefetch phase: the contiguous WS buffer
    is written back into the instance's (scattered) guest page slots.
    """
    n, page_elems = ws.shape
    n_pages = dest.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, page_elems), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, page_elems), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, page_elems), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pages, page_elems), ws.dtype),
        input_output_aliases={2: 0},  # dest (input, after the scalar op) -> out
        interpret=interpret,
    )(idx, ws, dest)
