"""Pure-jnp oracle for page_gather/page_scatter."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def page_gather_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(table, idx, axis=0)


def page_scatter_ref(ws: jax.Array, idx: jax.Array, n_pages: int) -> jax.Array:
    out = jnp.zeros((n_pages, ws.shape[1]), ws.dtype)
    return out.at[idx].set(ws)
