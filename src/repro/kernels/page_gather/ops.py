"""jit'd public wrappers for page_gather/page_scatter.

Handles lane padding (last dim to a multiple of 128) and dtype plumbing so
callers can hand in raw page buffers of any width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import page_gather as _gather, page_scatter as _scatter

LANE = 128


def _pad_lanes(x: jax.Array):
    pad = (-x.shape[1]) % LANE
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_pages(table: jax.Array, idx: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """Contiguous working set from a page table + trace (REAP record order)."""
    orig = table.shape[1]
    table, _ = _pad_lanes(table)
    out = _gather(table, idx.astype(jnp.int32), interpret=interpret)
    return out[:, :orig]


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(2,))
def scatter_pages(ws: jax.Array, idx: jax.Array, dest: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """Eager install: scatter the contiguous WS into the arena buffer."""
    orig = ws.shape[1]
    ws, _ = _pad_lanes(ws)
    dest_p, pad = _pad_lanes(dest)
    out = _scatter(ws, idx.astype(jnp.int32), dest_p, interpret=interpret)
    return out[:, :orig]
