"""Single-token GQA decode attention over a long KV cache, Pallas TPU.

One new query token attends over a KV cache of length S (up to 512k for the
long-context cells).  Grid (B, KV, n_kv_blocks): per kv head, the G grouped
query heads form the (G, D) q block (MXU-friendly), the online softmax state
(m, l, acc) persists in VMEM scratch across the sequential KV-block steps.
The valid cache length arrives as a scalar-prefetch operand so the DMA
schedule is known up front; padded KV blocks are masked.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, bk: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)

    limit = kv_len_ref[b]
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < limit, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new))
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, bk: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, D); k, v: (B, KV, S, D); kv_len: (B,) int32.

    Returns (B, KV, G, D).
    """
    B, KV, G, D = q.shape
    S = k.shape[2]
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, S // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, kv_len: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, kv_len: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, kv_len: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, kv_len: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
