"""Pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, KV, G, D); k, v: (B, KV, S, D); kv_len: (B,)."""
    B, KV, G, D = q.shape
    S = k.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]          # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
