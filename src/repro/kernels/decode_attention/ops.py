"""jit'd wrapper: standard cache layout in, lane padding, G >= 8 sublane
grouping."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention as _kernel

LANE = 128


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def gqa_decode(q: jax.Array, k: jax.Array, v: jax.Array, kv_len: jax.Array,
               *, bk: int = 512, interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, D); k, v: (B, S, KV, D); kv_len: (B,).

    Returns (B, 1, H, D): one decoded attention output per sequence.
    """
    B, _, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    pad = (-D) % LANE
    qg = q[:, 0].reshape(B, KV, G, D)
    kt = jnp.moveaxis(k, 1, 2)   # (B, KV, S, D)
    vt = jnp.moveaxis(v, 1, 2)
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad))) * ((D + pad) ** 0.5 / D ** 0.5)
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = _kernel(qg, kt, vt, kv_len, bk=bk, interpret=interpret)
    if pad:
        out = out[..., :D]
    return out.reshape(B, 1, H, D)
