"""jit'd wrapper: model layout in ((B, L, H, D) + per-head u)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_scan as _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, s0: jax.Array, *, chunk: int = 32,
         interpret: bool = True):
    """r, k, v, logw: (B, L, H, D); u: (H, D); s0: (B, H, D, D).

    Returns (y: (B, L, H, D), sT: (B, H, D, D))."""
    B, L, H, D = r.shape
    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, L, D)
    uf = jnp.tile(u, (B, 1))
    y, sT = _kernel(flat(r), flat(k), flat(v), flat(logw), uf,
                    s0.reshape(B * H, D, D), chunk=chunk, interpret=interpret)
    return (y.reshape(B, H, L, D).transpose(0, 2, 1, 3),
            sT.reshape(B, H, D, D))
