"""Recurrent oracle for WKV6 (also ground truth for models.rwkv6)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_scan_ref(r, k, v, logw, u, s0):
    """r, k, v, logw: (BH, L, D); u: (BH, D); s0: (BH, D, D)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(logw.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp            # (BH, D) each
        kv = kt[..., None] * vt[:, None, :]              # (BH, D, D)
        y = jnp.einsum("bi,bij->bj", rt, S + uf[..., None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    sT, ys = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (rf.transpose(1, 0, 2), kf.transpose(1, 0, 2),
         vf.transpose(1, 0, 2), wf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(r.dtype), sT
