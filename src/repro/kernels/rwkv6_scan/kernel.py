"""Chunked WKV6 recurrence (RWKV6 "Finch"), Pallas TPU.

Recurrence per head with *per-channel* data-dependent decay w_t (i = key
channel, j = value channel):

    y_t[j]   = sum_i r_t[i] * (S_{t-1}[i,j] + u[i] k_t[i] v_t[j])
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] v_t[j]

The CUDA wkv6 kernel is a per-timestep loop; the TPU-idiomatic form is the
chunked matrix evaluation: all pairwise intra-chunk decays are differences
of the cumulative log-decay (exponents <= 0, numerically safe), contracted
on the MXU; the (D x D) state persists in VMEM scratch across the
sequential chunk grid dimension.

Grid: (B*H, n_chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_ref, *, Lc: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)   # (Lc, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)   # log-decay, < 0
    u = u_ref[0].astype(jnp.float32)   # (1, D) bonus
    S = s_ref[...]                     # (D, D) key-major

    cum = jnp.cumsum(w, axis=0)        # inclusive d_t
    d_prev = cum - w                   # exclusive d_{t-1}
    # inter-chunk
    y = jax.lax.dot(r * jnp.exp(d_prev), S)            # (Lc, D)
    # intra-chunk, strictly causal: A[t,s] = sum_i r_t exp(d_prev_t - cum_s) k_s
    diff = d_prev[:, None, :] - cum[None, :, :]        # (Lc, Lc, D)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1))
    dec = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    A = jnp.einsum("ti,tsi,si->ts", r, dec, k,
                   preferred_element_type=jnp.float32)
    y = y + jax.lax.dot(A, v)
    # current-token bonus
    y = y + jnp.sum(r * u * k, axis=1, keepdims=True) * v
    y_ref[0] = y.astype(y_ref.dtype)
    # state: S' = Diag(exp(cum_L)) S + (k * exp(cum_L - cum))^T v
    last = cum[-1:]
    kdec = k * jnp.exp(last - cum)
    s_new = S * jnp.exp(last.T) + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())))
    s_ref[...] = s_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        sT_ref[0] = s_new.astype(sT_ref.dtype)


def wkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
              u: jax.Array, s0: jax.Array, *, chunk: int = 32,
              interpret: bool = True):
    """r, k, v, logw: (BH, L, D); u: (BH, D); s0: (BH, D, D).

    Returns (y: (BH, L, D), sT: (BH, D, D))."""
    BH, L, D = r.shape
    Lc = min(chunk, L)
    assert L % Lc == 0, (L, Lc)

    kernel = functools.partial(_wkv6_kernel, Lc=Lc)
    y, sT = pl.pallas_call(
        kernel,
        grid=(BH, L // Lc),
        in_specs=[
            pl.BlockSpec((1, Lc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Lc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, D, D), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, D, D), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u[:, None, :], s0)
    return y, sT
