"""jit'd wrapper: (B, S, H, D) layout in, head-dim padding to the MXU lane
width, block-size selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _kernel

LANE = 128


@functools.partial(jax.jit, static_argnames=("causal", "interpret", "bq", "bk"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        bq: int = 128, bk: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    pad = (-D) % LANE
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, pad)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, 0), (0, pad)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, 0), (0, pad)))
        # padded D inflates the softmax scale; rescale q to compensate
        qt = qt * ((D + pad) ** 0.5 / D ** 0.5)
    out = _kernel(qt, kt, vt, causal=causal, bq=bq, bk=bk, interpret=interpret)
    if pad:
        out = out[..., :D]
    return jnp.moveaxis(out, 1, 2)
