"""Pure-jnp oracle: exact softmax attention with GQA."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D)."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, D) / math.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, D).astype(q.dtype)
