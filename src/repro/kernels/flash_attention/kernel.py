"""Causal flash attention (prefill hot-spot), Pallas TPU.

Grid (B, H, n_q, n_kv) with the KV dimension innermost: the online-softmax
running max / normalizer / accumulator live in VMEM scratch and persist
across the sequential KV steps.  GQA is handled in the k/v index_map
(kv head = h // group) -- no KV duplication in HBM.  Block sizes are
MXU-aligned (multiples of 128 on the lane dim via ops.py padding).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, bq: int, bk: int, causal: bool):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    if causal:
        i = pl.program_id(2)
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D), H % KV == 0. Sq == Skv."""
    B, H, Sq, D = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, H, Sq // bq, Skv // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
