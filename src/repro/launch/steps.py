"""Step functions + input specs for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) -- the multi-pod dry-run lowers against these.
``make_batch`` materializes small real inputs for smoke tests / examples.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import get_family
from ..nn import spec as nnspec
from ..training import optimizer as opt_lib

# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------


def batch_shapes(cfg: ModelConfig, seq: int, batch: int,
                 kind: str) -> dict[str, tuple[tuple[int, ...], Any]]:
    """(shape, dtype) per input tensor for one step of ``kind``."""
    if kind == "decode":
        d: dict[str, tuple[tuple[int, ...], Any]] = {
            "tokens": ((batch, 1), jnp.int32)}
        return d
    d = {}
    if cfg.family == "vlm":
        n_txt = max(seq - cfg.n_patches, 1)
        d["tokens"] = ((batch, n_txt), jnp.int32)
        d["patch_embeds"] = ((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "encdec":
        d["tokens"] = ((batch, seq), jnp.int32)
        d["frames"] = ((batch, max(seq // cfg.frame_stride, 1), cfg.d_model),
                       jnp.bfloat16)
    else:
        d["tokens"] = ((batch, seq), jnp.int32)
    return d


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    shapes = batch_shapes(cfg, shape.seq_len, shape.global_batch, shape.kind)
    return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in shapes.items()}


def make_batch(cfg: ModelConfig, seq: int, batch: int, kind: str,
               key: jax.Array) -> dict[str, jax.Array]:
    out = {}
    for name, (shape, dtype) in batch_shapes(cfg, seq, batch, kind).items():
        key, sub = jax.random.split(key)
        if dtype == jnp.int32:
            out[name] = jax.random.randint(sub, shape, 0, cfg.vocab, jnp.int32)
        else:
            out[name] = (jax.random.normal(sub, shape, jnp.float32) * 0.02
                         ).astype(dtype)
    return out


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt: opt_lib.OptConfig, *,
                     remat: bool = True, remat_policy=None,
                     grad_dtype=jnp.float32, microbatches: int = 1,
                     grad_shardings=None, accum_dtype=jnp.float32):
    """Train step with optional gradient accumulation.

    ``microbatches > 1`` scans over batch slices accumulating grads --
    the standard activation-memory lever that lets the 100B-class cells
    fit per-chip HBM at global_batch 256 x 4096.  ``grad_shardings``
    (a params-shaped NamedSharding tree) pins the accumulator to the
    parameter sharding -- without it XLA replicates the f32 accumulator
    (embedding/lm-head grads alone are GBs per device at 150k vocab).
    """
    fam = get_family(cfg)

    def loss_fn(p, b):
        return fam.loss(cfg, p, b, remat=remat, remat_policy=remat_policy)

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain(grads)
        else:
            def slice_mb(i, x):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def accum(carry, i):
                loss_acc, grads_acc = carry
                mb = jax.tree.map(lambda x: slice_mb(i, x), batch)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                grads_acc = constrain(jax.tree.map(
                    lambda a, b_: a + b_.astype(accum_dtype), grads_acc, g))
                return (loss_acc + l, grads_acc), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros),
                jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.float32),
                                 grads)
        if grad_dtype != jnp.float32:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        new_params, new_state, metrics = opt_lib.apply_updates(
            params, grads, opt_state, opt)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def build_forward(cfg: ModelConfig):
    fam = get_family(cfg)

    def fwd(params, batch):
        return fam.forward(cfg, params, batch)

    return fwd


def build_prefill_step(cfg: ModelConfig):
    fam = get_family(cfg)

    def prefill_step(params, batch, cache):
        return fam.prefill(cfg, params, batch, cache)

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    fam = get_family(cfg)

    def decode_step(params, cache, batch, pos):
        return fam.decode(cfg, params, cache, batch, pos)

    return decode_step


def param_specs(cfg: ModelConfig):
    return get_family(cfg).param_specs(cfg)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return get_family(cfg).cache_specs(cfg, batch, max_len)


def init_params(cfg: ModelConfig, key: jax.Array):
    return nnspec.initialize(param_specs(cfg), key)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, key: jax.Array | None = None):
    return nnspec.initialize(cache_specs(cfg, batch, max_len),
                             key if key is not None else jax.random.key(0))
