"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the host-device override before any other import (jax locks the
device count on first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, shape_applicable
from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as shd
from ..distributed.hlo_analysis import Roofline, analyze_hlo, model_flops
from ..models import get_family
from ..nn import spec as nnspec
from ..training import optimizer as opt_lib
from . import steps as steps_lib
from .mesh import make_production_mesh


def active_params(cfg: ModelConfig, specs) -> tuple[int, int]:
    """(total, active) param counts; MoE active = shared + top_k/E routed."""
    total = expert = 0
    for path, s in nnspec.tree_paths(specs):
        total += s.size
        if "/moe/wi" in path or "/moe/wo" in path:
            expert += s.size
    if cfg.n_experts and expert:
        active = total - expert + expert * cfg.top_k / cfg.n_experts
    else:
        active = total
    return total, int(active)


def pick_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Activation-memory heuristic: keep per-device microbatch tokens
    around <= 64k for wide models."""
    per_dev_batch = max(shape.global_batch // shd.data_size(mesh), 1)
    tokens = per_dev_batch * shape.seq_len
    if cfg.d_model >= 8192:
        target = 4096      # ~80-layer models: keep saved carries ~5GB
    elif cfg.d_model >= 4096:
        target = 8192
    else:
        target = 16384
    micro = max(1, tokens // target)
    micro = min(micro, per_dev_batch)
    while per_dev_batch % micro and micro > 1:
        micro -= 1
    return micro


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               microbatches: int | None = None, fsdp: bool = True,
               remat: bool = True, grad_dtype="float32",
               donate: bool = True, remat_policy: str | None = None):
    """Build + lower the right step for one cell. Returns (lowered, meta)."""
    fam = get_family(cfg)
    policy = (getattr(jax.checkpoint_policies, remat_policy)
              if remat_policy else None)
    shd.set_activation_rules(mesh, shape.global_batch)
    rules = shd.make_rules(mesh, batch=shape.global_batch, fsdp=fsdp)
    pspecs = fam.param_specs(cfg)
    params_abs = nnspec.abstract(pspecs)
    params_sh = nnspec.shardings(pspecs, mesh, rules)
    bspec = shd.batch_pspec(mesh, shape.global_batch)
    in_specs = steps_lib.input_specs(cfg, shape)
    batch_sh = {k: NamedSharding(mesh, P(bspec[0], *([None] * (len(v.shape) - 1))))
                for k, v in in_specs.items()}

    if shape.kind == "train":
        opt = opt_lib.OptConfig()
        ospecs = opt_lib.state_specs(pspecs, opt)
        opt_abs = nnspec.abstract(ospecs)
        opt_sh = nnspec.shardings(ospecs, mesh, rules)
        micro = microbatches or pick_microbatches(cfg, shape, mesh)
        step = steps_lib.build_train_step(
            cfg, opt, remat=remat, microbatches=micro,
            grad_dtype=jnp.dtype(grad_dtype),
            grad_shardings=params_sh, remat_policy=policy)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, opt_sh, batch_sh),
                         out_shardings=(params_sh, opt_sh, None),
                         donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(params_abs, opt_abs, in_specs)
        return lowered, {"microbatches": micro}

    if shape.kind == "prefill":
        cspecs = fam.cache_specs(cfg, shape.global_batch, shape.seq_len)
        cache_abs = nnspec.abstract(cspecs)
        cache_sh = nnspec.shardings(cspecs, mesh, rules)
        step = steps_lib.build_prefill_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(params_abs, in_specs, cache_abs)
        return lowered, {}

    # decode: one new token against a seq_len cache
    cspecs = fam.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cache_abs = nnspec.abstract(cspecs)
    cache_sh = nnspec.shardings(cspecs, mesh, rules)
    step = steps_lib.build_decode_step(cfg)
    tok_abs = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)}
    tok_sh = {"tokens": NamedSharding(mesh, P(bspec[0], None))}
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step,
                     in_shardings=(params_sh, cache_sh, tok_sh,
                                   NamedSharding(mesh, P())),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,) if donate else ())
    lowered = jitted.lower(params_abs, cache_abs, tok_abs, pos_abs)
    return lowered, {}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             cfg_overrides: dict | None = None, tag: str = "",
             **overrides) -> dict:
    import dataclasses as _dc
    cfg = ARCHS[arch]
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "tag": tag, "cfg_overrides": cfg_overrides or {},
              "overrides": {k: str(v) for k, v in overrides.items()}}
    if not ok:
        result.update(status="skipped", reason=why)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            suffix = f"__{tag}" if tag else ""
            with open(os.path.join(out_dir,
                      f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"),
                      "w") as f:
                json.dump(result, f, indent=1)
        return result
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    t0 = time.perf_counter()
    try:
        try:
            lowered, meta = lower_cell(cfg, shape, mesh, **overrides)
        finally:
            shd.set_activation_rules(None)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        analysis = analyze_hlo(compiled.as_text())
        specs = get_family(cfg).param_specs(cfg)
        total_p, active_p = active_params(cfg, specs)
        mf = model_flops(cfg, shape, total_p, active_p)
        roof = Roofline(
            flops=analysis["dot_flops_per_device"],
            hbm_bytes=analysis["hbm_bytes_per_device"],
            coll_bytes=float(sum(analysis["collective_bytes_per_device"].values())),
            n_chips=n_chips,
            model_flops=mf,
        )
        per_dev = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        }
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        result.update(
            status="ok", meta=meta,
            n_chips=n_chips,
            params_total=total_p, params_active=active_p,
            memory_per_device=per_dev,
            peak_bytes_per_device=peak,
            fits_hbm=bool(peak < 16e9),
            collectives={"bytes": analysis["collective_bytes_per_device"],
                         "count": analysis["collective_count"]},
            cost_analysis_raw={k: float(v) for k, v in cost.items()
                               if k in ("flops", "bytes accessed")},
            roofline=roof.to_dict(),
            lower_s=t_lower, compile_s=t_compile,
        )
    except Exception as e:  # a failure here is a bug in the system
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                fn = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
                if args.skip_existing and os.path.exists(fn):
                    with open(fn) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached ] {arch} x {shape} x {mk}: {prev['status']}")
                        continue
                r = run_cell(arch, shape, mk, args.out)
                if r["status"] == "ok":
                    roof = r["roofline"]
                    print(f"[ok     ] {arch} x {shape} x {mk}: "
                          f"peak/dev={r['peak_bytes_per_device']/1e9:.2f}GB "
                          f"bottleneck={roof['bottleneck']} "
                          f"step={roof['step_s']*1e3:.1f}ms "
                          f"(lower {r['lower_s']:.0f}s compile {r['compile_s']:.0f}s)")
                elif r["status"] == "skipped":
                    print(f"[skipped] {arch} x {shape} x {mk}: {r['reason']}")
                else:
                    failures += 1
                    print(f"[ERROR  ] {arch} x {shape} x {mk}: {r['error']}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
