"""Production meshes.

Functions (not module-level constants) so importing never touches jax
device state; the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import so ``jax.make_mesh`` can build these shapes on the CPU host.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_smoke_mesh():
    """1x1 mesh for CPU smoke tests (single real device)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
