"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the orchestrator, registers the function (building its snapshot if
needed), then drives cold / REAP-cold / warm invocations and prints the
paper-style latency breakdown.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--store", default=".serve_store")
    ap.add_argument("--mode", default="reap", choices=["reap", "vanilla"])
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    import jax

    from ..configs import SMOKES
    from ..core import ReapConfig
    from ..launch import steps as steps_lib
    from ..serving import Orchestrator

    cfg = SMOKES[args.arch]
    orch = Orchestrator(args.store, mode=args.mode, reap=ReapConfig())
    batch = steps_lib.make_batch(cfg, args.seq, args.batch, "train",
                                 jax.random.key(0))
    orch.register(args.arch, cfg, warmup_batch=batch)

    for i in range(args.requests):
        force_cold = i == 0
        if i == 1:
            orch.scale_to_zero(args.arch)  # second request is a REAP cold start
        _, r = orch.invoke(args.arch, batch, force_cold=force_cold)
        kind = ("cold" if r.n_faults or r.n_prefetched_pages else "warm")
        print(f"req{i} [{kind:4s}] load_vmm={r.load_vmm_s*1e3:6.1f}ms "
              f"conn={r.connection_s*1e3:5.2f}ms "
              f"prefetch={r.prefetch_s*1e3:6.1f}ms "
              f"processing={r.processing_s*1e3:7.1f}ms "
              f"faults={r.n_faults}")


if __name__ == "__main__":
    main()
