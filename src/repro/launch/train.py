"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

End-to-end driver: synthetic corpus -> fault-tolerant train loop (async
checkpoints through the snapshot substrate, REAP-accelerated restart).
On this CPU container use ``--smoke`` (reduced config); the full configs
are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--workdir", default=".train")
    ap.add_argument("--restore-mode", default="reap", choices=["reap", "lazy"])
    ap.add_argument("--preempt-at", type=int, default=None,
                    help="simulate preemption at this step (fault-tolerance demo)")
    args = ap.parse_args()

    from ..configs import ARCHS, SMOKES
    from ..data import synthesize_corpus
    from ..training import (OptConfig, SimulatedPreemption, Trainer,
                            TrainLoopConfig)

    cfg = SMOKES[args.arch] if args.smoke else ARCHS[args.arch]
    os.makedirs(args.workdir, exist_ok=True)
    corpus = synthesize_corpus(
        os.path.join(args.workdir, f"corpus_{cfg.vocab}.bin"),
        max(args.steps * args.batch * args.seq * 2, 200_000), cfg.vocab)

    loop = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        batch_size=args.batch, seq_len=args.seq,
        restore_mode=args.restore_mode)
    tr = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=5,
                                total_steps=args.steps),
                 loop, corpus, os.path.join(args.workdir, "ckpt"),
                 preempt_at=args.preempt_at)
    try:
        out = tr.run()
    except SimulatedPreemption as e:
        print(f"!! {e} -- restart with the same command to resume")
        return
    print(f"arch={cfg.name} steps={out['final_step']} "
          f"loss[0]={out['losses'][0]:.4f} loss[-1]={out['losses'][-1]:.4f} "
          f"({out['seconds']:.1f}s)")
    if out["restore_stats"]:
        rs = out["restore_stats"]
        print(f"restored via {args.restore_mode}: {rs['bytes']/1e6:.1f}MB "
              f"in {rs['io_s']*1e3:.1f}ms ({rs['n_faults']} faults)")


if __name__ == "__main__":
    main()
